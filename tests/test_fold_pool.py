"""Shard-parallel host execution: the worker-count invariance contract.

The PR's load-bearing property: ``avg_flat`` (and every modeled counter —
op counts, billed GB-s) is **bit-identical at every worker count**,
because the fold pool splits the element axis only and each worker
replays the exact sequential IEEE op order inside its span. Pinned here
at three layers:

  * unit — ``partition``/``spans``/``run_spans``/``map`` determinism;
  * evaluator — the batched DAG pass and the population engine's chunked
    ``np.add.accumulate`` replays, driven with small-chunk pools so real
    multi-span splits happen on test-sized arrays;
  * end-to-end — ``workers ∈ {1,2,4,8}`` × engine × topology × codec
    through the public drivers (plus the population engine and a seeded
    arrival-permutation property under the pipelined schedule).
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                  # pragma: no cover
    from _hypothesis_fallback import given, settings
    from _hypothesis_fallback import strategies as st

from repro.api import FederatedSession, SessionConfig
from repro.core import agg_engine, fold_pool
from repro.core.agg_engine import BatchedBackend, LazyAverage
from repro.core.cost_model import UploadModel
from repro.core.fold_pool import (CHUNK_ELEMS, ParallelFoldPool, get_pool,
                                  partition)
from repro.serverless.population import ClientPopulation, _fold_chunks
from repro.store import ObjectStore

WORKER_GRID = (1, 2, 4, 8)


def _grads(n=6, size=2_003, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.standard_normal(size).astype(np.float32) for _ in range(n)]


def _small_pool(workers, chunk=64):
    return ParallelFoldPool(workers, chunk=chunk, min_parallel_elems=1)


# ---------------------------------------------------------------------------
# partition / spans: the deterministic split
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("size", [1, 63, 64, 65, 1_000, 4_096, 5_003])
@pytest.mark.parametrize("workers", WORKER_GRID)
def test_partition_covers_exactly_in_order(size, workers):
    spans = partition(size, workers, chunk=64)
    assert spans[0][0] == 0 and spans[-1][1] == size
    for (lo, hi), (lo2, _hi2) in zip(spans, spans[1:]):
        assert hi == lo2                     # contiguous, ascending
    for lo, hi in spans:
        assert lo < hi
    assert len(spans) <= workers
    # every interior boundary is chunk-aligned, so a worker's chunk walk
    # lines up with the single-threaded evaluator's
    for lo, _hi in spans[1:]:
        assert lo % 64 == 0


def test_partition_is_pure():
    assert partition(100_000, 4) == partition(100_000, 4)
    assert partition(0, 4) == []
    assert partition(-3, 4) == []
    assert partition(100, 1) == [(0, 100)]


def test_spans_threshold_and_worker_gate():
    pool = ParallelFoldPool(4, chunk=64, min_parallel_elems=1_000)
    assert pool.spans(999) == [(0, 999)]     # below threshold: inline
    assert len(pool.spans(1_000)) > 1        # at threshold: split
    assert pool.spans(0) == []
    assert ParallelFoldPool(1).spans(1 << 22) == [(0, 1 << 22)]


def test_run_spans_executes_all_and_propagates_errors():
    pool = _small_pool(4)
    seen = {}

    def fn(lo, hi):
        seen[lo] = hi

    pool.run_spans(fn, 1_000)
    assert sorted((lo, hi) for lo, hi in seen.items()) == pool.spans(1_000)

    def boom(lo, hi):
        raise RuntimeError("span failed")

    with pytest.raises(RuntimeError, match="span failed"):
        pool.run_spans(boom, 1_000)
    pool.close()


def test_map_keeps_task_order():
    pool = _small_pool(4)
    out = pool.map(lambda a, b: a * b, [(i, 2) for i in range(37)])
    assert out == [i * 2 for i in range(37)]
    pool.close()


def test_default_pool_threshold_keeps_small_folds_inline():
    # test-sized folds never pay the thread hand-off on the shared pools
    assert get_pool(8).spans(100_000) == [(0, 100_000)]


# ---------------------------------------------------------------------------
# batched DAG evaluator: real multi-span splits, bit-identical
# ---------------------------------------------------------------------------

def _dag_nodes(size=5_003, n=7, seed=1):
    """An unweighted node, a weighted node, and a second-level node whose
    inputs include the first (lazy-ancestor ordering under the pool)."""
    rng = np.random.default_rng(seed)
    ins = [rng.standard_normal(size).astype(np.float32) for _ in range(n)]
    leaf = LazyAverage(ins[:4], None)
    weighted = LazyAverage(ins[4:], [1.0, 0.5, 2.0])
    root = LazyAverage([leaf, ins[1], ins[2]], None)
    return [leaf, weighted, root]


def test_evaluate_nodes_bit_identical_across_worker_counts():
    ref = None
    for workers in WORKER_GRID:
        nodes = _dag_nodes()
        agg_engine._evaluate_nodes(nodes, chunk=64,
                                   pool=_small_pool(workers))
        outs = [nd.out for nd in nodes]
        assert all(len(partition(nd.size, workers, 64)) ==
                   (min(workers, -(-nd.size // 64)) if workers > 1 else 1)
                   for nd in nodes)
        if ref is None:
            ref = outs
        else:
            for a, b in zip(ref, outs):
                np.testing.assert_array_equal(a, b)


def test_evaluate_nodes_matches_streaming_reference():
    nodes = _dag_nodes()
    agg_engine._evaluate_nodes(nodes, chunk=64, pool=_small_pool(8))
    leaf, weighted, _root = nodes
    acc = leaf.inputs[0].astype(np.float32).copy()
    for x in leaf.inputs[1:]:
        acc += x
    np.testing.assert_array_equal(leaf.out,
                                  (acc / float(len(leaf.inputs)))
                                  .astype(np.float32))
    w = weighted.weights
    wacc = weighted.inputs[0].astype(np.float64) * w[0]
    for i in range(1, 3):
        wacc += weighted.inputs[i].astype(np.float64) * w[i]
    np.testing.assert_array_equal(
        weighted.out, (wacc / float(sum(w))).astype(np.float32))


def test_chunk_size_never_changes_bits():
    base = None
    for chunk in (32, 64, 1_000, CHUNK_ELEMS):
        nodes = _dag_nodes()
        agg_engine._evaluate_nodes(nodes, chunk=chunk, pool=_small_pool(4))
        if base is None:
            base = [nd.out for nd in nodes]
        else:
            for a, nd in zip(base, nodes):
                np.testing.assert_array_equal(a, nd.out)


# ---------------------------------------------------------------------------
# population value plane: column-axis splits, bit-identical
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("weighted", [False, True])
def test_fold_chunks_bit_identical_across_worker_counts(weighted):
    rng = np.random.default_rng(5)
    chunks = [rng.standard_normal((4, 1_003)).astype(np.float32)
              for _ in range(3)]
    ref = _fold_chunks(iter([c.copy() for c in chunks]), weighted, 12,
                       pool=None)
    for workers in WORKER_GRID:
        got = _fold_chunks(iter([c.copy() for c in chunks]), weighted, 12,
                           pool=_small_pool(workers, chunk=128))
        np.testing.assert_array_equal(ref, got)


# ---------------------------------------------------------------------------
# end-to-end: workers grid x engine x topology x codec
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("codec", ["identity", "qsgd8"])
@pytest.mark.parametrize("topology", ["gradssharding", "lambda_fl", "lifl"])
def test_worker_grid_invariance(topology, codec):
    grads = _grads()
    ref = {}
    for engine in ("streaming", "batched", "incremental"):
        for workers in WORKER_GRID:
            session = FederatedSession(SessionConfig(
                topology=topology, n_shards=4, engine=engine, codec=codec,
                workers=workers))
            r = session.round(grads)
            sig = (r.puts, r.gets, r.wall_clock_s,
                   sum(rec.billed_gb_s for rec in r.records))
            if not ref:
                ref = {"avg": r.avg_flat, "sig": sig}
            # bit-identity AND accounting invariance across the whole
            # workers x engine plane (per topology x codec)
            assert np.array_equal(r.avg_flat, ref["avg"]), \
                (engine, workers)
            assert sig == ref["sig"], (engine, workers)


def test_worker_grid_population_engine():
    pop = ClientPopulation(n_clients=96, grad_elems=1_024, seed=7)
    ref = None
    for workers in WORKER_GRID:
        session = FederatedSession(SessionConfig(
            topology="gradssharding", n_shards=4, population=pop,
            workers=workers, log_ops=False))
        r = session.round()
        if ref is None:
            ref = r
        else:
            assert np.array_equal(r.avg_flat, ref.avg_flat), workers
            assert (r.puts, r.gets) == (ref.puts, ref.gets)
            assert r.wall_clock_s == ref.wall_clock_s


def test_worker_grid_real_splits_through_run_round():
    """Force actual multi-span parallel evaluation through the public
    driver: inject small-threshold pools into the process cache so the
    default CHUNK_ELEMS alignment still yields several spans."""
    size = 3 * CHUNK_ELEMS + 17
    grads = _grads(n=4, size=size, seed=9)
    saved = dict(fold_pool._POOLS)
    try:
        ref = None
        for workers in (1, 2, 4):
            fold_pool._POOLS.clear()
            fold_pool._POOLS[workers] = ParallelFoldPool(
                workers, min_parallel_elems=1)
            assert len(fold_pool._POOLS[workers].spans(size)) == \
                min(workers, 4)
            session = FederatedSession(SessionConfig(
                topology="lambda_fl", engine="batched", workers=workers))
            r = session.round(grads)
            if ref is None:
                ref = r.avg_flat
            else:
                assert np.array_equal(r.avg_flat, ref), workers
    finally:
        fold_pool._POOLS.clear()
        fold_pool._POOLS.update(saved)


# ---------------------------------------------------------------------------
# arrival permutations x workers: the pipelined fold order is by client
# index, so jittered upload arrival order never changes bits either
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1),
       workers=st.sampled_from(WORKER_GRID))
def test_arrival_permutation_property(seed, workers):
    grads = _grads(n=8, size=769, seed=3)
    barrier = FederatedSession(SessionConfig(
        topology="gradssharding", n_shards=2, engine="streaming",
        workers=1)).round(grads)
    jitter = UploadModel(mbps=16.0, jitter_s=5.0, rate_jitter=0.5,
                         seed=seed)
    piped = FederatedSession(SessionConfig(
        topology="gradssharding", n_shards=2, engine="batched",
        schedule="pipelined", readahead_k=4, upload=jitter,
        workers=workers)).round(grads)
    assert np.array_equal(piped.avg_flat, barrier.avg_flat)
    assert (piped.puts, piped.gets) == (barrier.puts, barrier.gets)


# ---------------------------------------------------------------------------
# kernels: bucketed interpret-mode dispatch
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_fedavg_multi_worker_buckets_bit_identical():
    from repro.kernels import ops
    rng = np.random.default_rng(13)
    stacks = [rng.standard_normal((5, l)).astype(np.float32)
              for l in (300, 640, 7, 1_024)]
    ref = [np.asarray(v) for v in ops.fedavg_multi(stacks, workers=1)]
    for workers in (2, 4, 8):
        got = ops.fedavg_multi(stacks, workers=workers)
        for a, b in zip(ref, got):
            np.testing.assert_array_equal(a, np.asarray(b))
