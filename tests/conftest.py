# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# host's single real device. Multi-device tests spawn subprocesses with
# --xla_force_host_platform_device_count set (see test_distributed.py).
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
