"""Million-client cohort engine: lazy schedules + virtualized folds.

The contracts under test:

  * **lazy stream gathering** — ``gather_stream`` returns exactly
    ``draw(default_rng(key), N)[idx]`` (bit-identical) for arbitrary
    unique index subsets in any order, including the ``skip`` offset used
    when several vectors are drawn from one stream; the lazy
    ``plan_at``/``compute_plan_at``/``participants_arr``/``dropout_at``/
    ``stall_at`` entries slice their eager twins exactly.
  * **lazy ≡ eager** — ``run_population_round`` reproduces
    :func:`repro.core.topology.run_round` over ``pop.materialize(rnd)``
    bit-for-bit on every observable: ``avg_flat`` bytes, walls, phase
    times, op/byte counts, billed GB-s, every invocation record field,
    per-client read-back times, membership arrays, codec error — across
    topologies × schedules × codecs × faults × quorum/deadline knobs.
  * **O(active) residency** — a round over a 10^5-client cohort with a
    small participating subset peaks far below the eager driver's
    O(N·|grad|) floor (tracemalloc-measured).
  * **honest refusals** — staleness re-entry, hedging, LIFL's colocated
    path and unregistered topologies raise ``NotImplementedError``
    rather than silently diverging.
"""
import dataclasses
import tracemalloc

import numpy as np
import pytest

from repro.api import FederatedSession, SessionConfig
from repro.core.cost_model import UploadModel
from repro.core.topology import run_round
from repro.serverless.faults import FaultModel, StalenessPolicy
from repro.serverless.population import (ClientPopulation,
                                         population_topologies,
                                         run_population_round)
from repro.serverless.runtime import LambdaRuntime
from repro.serverless.streams import gather_stream
from repro.store import ObjectStore

TOPOLOGIES = ("gradssharding", "lambda_fl", "lifl", "geo_tiered")

UPLOAD = UploadModel(mbps=12.0, jitter_s=0.4, rate_jitter=0.3,
                     compute_s=0.2, compute_jitter=0.1, seed=5)
FAULTS = FaultModel(seed=11, dropout_rate=0.15, stall_rate=0.2, stall_s=1.5,
                    failure_rate=0.25)


# ---------------------------------------------------------------------------
# gather_stream: lazy slices of seeded vectorized draws
# ---------------------------------------------------------------------------

def _full(key, n, draw=lambda r, m: r.random(m)):
    return draw(np.random.default_rng(key), n)


@pytest.mark.parametrize("idx", [
    [0], [999], [0, 1, 2], [5, 17, 18, 19, 500],
    list(range(1000)), list(range(0, 1000, 7)), [998, 999],
])
def test_gather_stream_matches_full_draw(idx):
    key = [3, 7]
    full = _full(key, 1000)
    got = gather_stream(key, idx, lambda r, m: r.random(m))
    assert got.tobytes() == full[np.asarray(idx)].tobytes()


def test_gather_stream_unsorted_and_uniform():
    key = [9, 1]
    rng = np.random.default_rng(0)
    idx = rng.permutation(500)[:73]
    full = _full(key, 500, lambda r, m: r.uniform(0.0, 3.0, m))
    got = gather_stream(key, idx, lambda r, m: r.uniform(0.0, 3.0, m))
    assert got.tobytes() == full[idx].tobytes()


def test_gather_stream_skip_offset():
    # UploadModel.plan draws starts then mults from ONE stream: the mults
    # slice must skip the n starts draws exactly
    key = [4, 2]
    rng = np.random.default_rng(key)
    rng.uniform(0.0, 1.0, 200)                       # starts
    mults = rng.uniform(0.0, 0.5, 200)               # then mults
    got = gather_stream(key, [3, 77, 150],
                        lambda r, m: r.uniform(0.0, 0.5, m), skip=200)
    assert got.tobytes() == mults[[3, 77, 150]].tobytes()


def test_gather_stream_rejects_bad_idx():
    with pytest.raises(ValueError):
        gather_stream([1], [3, 3], lambda r, m: r.random(m))
    with pytest.raises(ValueError):
        gather_stream([1], [-1, 2], lambda r, m: r.random(m))
    assert len(gather_stream([1], [], lambda r, m: r.random(m))) == 0


def test_lazy_model_entries_slice_eager_twins():
    up = UPLOAD
    n, rnd = 300, 4
    idx = np.array([0, 7, 8, 9, 150, 299])
    s_full, m_full = up.plan(n, rnd)
    c_full = up.compute_plan(n, rnd)
    s_lazy, m_lazy = up.plan_at(n, rnd, idx)
    assert s_lazy.tobytes() == np.asarray(s_full)[idx].tobytes()
    assert m_lazy.tobytes() == np.asarray(m_full)[idx].tobytes()
    assert up.compute_plan_at(n, rnd, idx).tobytes() == \
        np.asarray(c_full)[idx].tobytes()
    fm = FAULTS
    assert np.array_equal(fm.participants_arr(n, rnd, n), np.arange(n))
    assert tuple(fm.participants_arr(n, rnd, 40).tolist()) == \
        fm.participants(n, rnd, 40)
    assert fm.dropout_at(n, rnd, idx).tobytes() == \
        fm.dropout_plan(n, rnd)[idx].tobytes()
    assert fm.stall_at(n, rnd, idx).tobytes() == \
        fm.stall_plan(n, rnd)[idx].tobytes()


# ---------------------------------------------------------------------------
# ClientPopulation
# ---------------------------------------------------------------------------

def test_population_deterministic_and_sliceable():
    pop = ClientPopulation(50, grad_elems=33, seed=2)
    full = pop.grads(3, np.arange(50))
    assert pop.grads(3, [5, 17]).tobytes() == full[[5, 17]].tobytes()
    assert np.concatenate(
        list(pop.iter_grads(3, np.arange(50), chunk=7))).tobytes() \
        == full.tobytes()
    mats = pop.materialize(3)
    assert len(mats) == 50 and mats[11].tobytes() == full[11].tobytes()
    # different rounds share per-client scale but move the direction
    assert pop.grads(4, [5]).tobytes() != pop.grads(3, [5]).tobytes()
    with pytest.raises(ValueError):
        ClientPopulation(0)
    with pytest.raises(ValueError):
        ClientPopulation(5, grad_elems=0)


# ---------------------------------------------------------------------------
# lazy ≡ eager bit-identity
# ---------------------------------------------------------------------------

def _compare(topo, n=23, rnd=3, elems=257, seed=7, **kw):
    pop = ClientPopulation(n, grad_elems=elems, seed=seed)
    st_e, rt_e = ObjectStore(), LambdaRuntime()
    r_e = run_round(topo, pop.materialize(rnd), rnd=rnd, store=st_e,
                    runtime=rt_e, **kw)
    st_p, rt_p = ObjectStore(), LambdaRuntime()
    r_p = run_population_round(topo, pop, rnd=rnd, store=st_p,
                               runtime=rt_p, **kw)
    assert r_p.avg_flat.tobytes() == r_e.avg_flat.tobytes()
    assert r_p.wall_clock_s == r_e.wall_clock_s
    assert tuple(r_p.phases_s) == tuple(r_e.phases_s)
    assert (r_p.puts, r_p.gets) == (r_e.puts, r_e.gets)
    assert (st_p.stats.bytes_written, st_p.stats.bytes_read) == \
        (st_e.stats.bytes_written, st_e.stats.bytes_read)
    assert sum(r.billed_gb_s for r in rt_p.records) == \
        sum(r.billed_gb_s for r in rt_e.records)
    assert np.asarray(r_p.client_done_s).tobytes() == \
        np.asarray(r_e.client_done_s).tobytes()
    assert tuple(r_p.participants) == tuple(r_e.participants)
    assert tuple(r_p.arrivals) == tuple(r_e.arrivals)
    assert tuple(r_p.dropped) == tuple(r_e.dropped)
    assert tuple(r_p.late) == tuple(r_e.late)
    assert len(r_p.records) == len(r_e.records)
    for a, b in zip(r_e.records, r_p.records):
        assert dataclasses.astuple(a) == dataclasses.astuple(b), a.fn_name
    assert r_p.codec_error == r_e.codec_error
    assert r_p.retries == r_e.retries
    assert r_p.round_end_s == r_e.round_end_s
    assert (r_p.memory_mb, r_p.peak_memory_mb) == \
        (r_e.memory_mb, r_e.peak_memory_mb)
    return r_p


@pytest.mark.parametrize("topology", TOPOLOGIES)
@pytest.mark.parametrize("schedule", [None, "barrier", "pipelined"])
def test_population_matches_eager(topology, schedule):
    _compare(topology, schedule=schedule, upload=UPLOAD)


@pytest.mark.parametrize("topology", TOPOLOGIES)
def test_population_matches_eager_under_faults(topology):
    _compare(topology, schedule="pipelined", upload=UPLOAD, faults=FAULTS,
             participation_k=18, straggler_threshold_s=0.5)
    _compare(topology, schedule="quorum", quorum=10, upload=UPLOAD,
             faults=FAULTS, participation_k=18)


@pytest.mark.parametrize("codec", ["identity", "fp16", "qsgd8", "topk"])
def test_population_matches_eager_codecs(codec):
    _compare("gradssharding", codec=codec, upload=UPLOAD)
    _compare("geo_tiered", codec=codec, upload=UPLOAD, schedule="barrier")


@pytest.mark.parametrize("topology", TOPOLOGIES)
def test_population_matches_eager_deadline_quorum(topology):
    _compare(topology, upload=UPLOAD, deadline_s=1.0)
    _compare(topology, upload=UPLOAD, schedule="quorum", quorum=8,
             deadline_s=2.0)


def test_population_matches_eager_edges_and_options():
    for topo in TOPOLOGIES:
        _compare(topo, n=1, upload=UPLOAD)
        _compare(topo, n=2, upload=UPLOAD)
        _compare(topo, upload=None)                  # no upload model
        _compare(topo, upload=UPLOAD, readahead_k=4)
        _compare(topo, upload=UPLOAD,
                 client_ready_s=list(np.linspace(0.0, 3.0, 23)))
    _compare("gradssharding", upload=UPLOAD, n_shards=7)
    _compare("gradssharding", upload=UPLOAD, partition="balanced",
             n_shards=3, tensor_sizes=(64, 129, 64))
    _compare("geo_tiered", upload=UPLOAD, edge_fanin=3, region_fanin=2,
             edge_mbps=20.0, backbone_mbps=300.0)


def test_population_session_multi_round_matches_eager():
    pop = ClientPopulation(31, grad_elems=129, seed=3)
    cfg = dict(topology="geo_tiered", schedule="pipelined", upload=UPLOAD,
               faults=FAULTS, participation_k=24, codec="fp16")
    se = FederatedSession(SessionConfig(**cfg))
    sp = FederatedSession(SessionConfig(population=pop, **cfg))
    for rnd in range(4):
        r_e = se.round(pop.materialize(rnd))
        r_p = sp.round()
        assert r_p.avg_flat.tobytes() == r_e.avg_flat.tobytes()
        assert r_p.wall_clock_s == r_e.wall_clock_s
        assert np.asarray(r_p.client_done_s).tobytes() == \
            np.asarray(r_e.client_done_s).tobytes()
    assert sp.summary() == se.summary()


def test_population_session_compaction_and_log_ops():
    pop = ClientPopulation(200, grad_elems=64, seed=3)
    kw = dict(topology="lambda_fl", upload=UPLOAD, track_codec_error=False)
    ref = FederatedSession(SessionConfig(population=pop, **kw))
    lean = FederatedSession(SessionConfig(population=pop, log_ops=False,
                                          keep_records=False, **kw))
    for _ in range(3):
        r_ref = ref.round()
        r_lean = lean.round()
        assert r_lean.avg_flat.tobytes() == r_ref.avg_flat.tobytes()
    s_ref, s_lean = ref.summary(), lean.summary()
    for key in ("total_cost", "puts", "gets", "session_wall_s"):
        assert s_lean[key] == s_ref[key]
    assert lean.store.stats.put_log == []            # logs skipped
    assert lean.runtime.records == []                # compacted
    assert len(ref.store.stats.put_log) > 0


# ---------------------------------------------------------------------------
# refusals and registry
# ---------------------------------------------------------------------------

def test_population_refuses_unsupported_knobs():
    pop = ClientPopulation(8, grad_elems=32)
    kw = dict(rnd=0, store=ObjectStore(), runtime=LambdaRuntime())
    with pytest.raises(NotImplementedError, match="staleness"):
        run_population_round("lambda_fl", pop,
                             staleness_policy=StalenessPolicy(), **kw)
    with pytest.raises(NotImplementedError, match="hedg"):
        run_population_round("lambda_fl", pop, schedule="pipelined",
                             hedge_factor=1.5, **kw)
    with pytest.raises(NotImplementedError, match="colocated"):
        run_population_round("lifl", pop, colocated=True, **kw)
    with pytest.raises(NotImplementedError, match="population entry"):
        run_population_round("sharded_tree", pop, **kw)
    with pytest.raises(ValueError, match="client_grads"):
        FederatedSession(SessionConfig(population=pop)).round(
            [np.zeros(32, np.float32)])
    with pytest.raises(ValueError, match="client_grads"):
        FederatedSession(SessionConfig()).round()
    assert set(TOPOLOGIES) <= set(population_topologies())


# ---------------------------------------------------------------------------
# O(active) residency
# ---------------------------------------------------------------------------

def test_population_round_is_o_active_memory():
    # 10^5-client cohort, 512 sampled participants, 4096-elem gradients:
    # the eager driver's client gradients alone would be
    # N * 4096 * 4 B = 1.6 GB; the population engine must stay orders of
    # magnitude below that (transients: O(K) schedule columns + one
    # CHUNK_ROWS x grad batch + O(N) bits for the membership draw).
    n = 100_000
    pop = ClientPopulation(n, grad_elems=4096, seed=1)
    store = ObjectStore(log_ops=False)
    runtime = LambdaRuntime()
    tracemalloc.start()
    r = run_population_round(
        "geo_tiered", pop, rnd=0, store=store, runtime=runtime,
        upload=UPLOAD, faults=FAULTS, participation_k=512,
        track_codec_error=False)
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    assert peak < 64 * 1024 * 1024, f"peak {peak / 1e6:.1f} MB"
    assert len(r.arrivals) <= 512 and r.wall_clock_s > 0.0
    # the cohort-sized result arrays are the only O(N) state
    assert len(r.client_done_s) == n
