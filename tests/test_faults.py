"""Fault-tolerant rounds: seeded dropout/stall/failure injection, retries
with backoff, round deadlines, partial participation and the quorum-gated
semi-async fold.

The contracts under test:

  * **determinism** — every seeded stream replays bit-identically: same
    seed => identical participant set, dropout set, retry timeline, fold
    order and ``avg_flat`` bits across engines and topologies; different
    seeds => different participant sets.
  * **zero-fault no-op** — an all-default ``FaultModel`` (and every knob
    left ``None``) reproduces the fault-free driver path bit-for-bit.
  * **subset-fold correctness** — for any surviving membership the round
    average equals the plain mean over the survivors' gradients, on all
    three engines (membership is program-level; engines stay unaware).
  * **graceful degradation** — injected aggregator failures retry (with
    exponential backoff) and the round always completes within the
    runtime's attempt budget; the result reports ``delivered_fraction``,
    ``retries``, ``dropped``/``late`` honestly.
"""
import dataclasses

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # bare env: deterministic fallback
    from _hypothesis_fallback import given, settings
    from _hypothesis_fallback import strategies as st

from repro.api import FederatedSession, SessionConfig
from repro.core import cost_model as cm
from repro.core.topology import run_round, validate_fault_knobs
from repro.serverless import FaultModel, FaultPlan, LambdaRuntime, \
    fault_model_from_env
from repro.store import ObjectStore

ENGINES = ("streaming", "batched", "incremental")
TOPOLOGIES = ("gradssharding", "lambda_fl", "lifl", "sharded_tree")

UPLOAD = cm.UploadModel(mbps=16.0, jitter_s=3.0, rate_jitter=0.5, seed=11)
FAULTS = FaultModel(dropout_rate=0.2, stall_rate=0.2, stall_s=4.0,
                    failure_rate=0.3, retry_backoff_s=0.5, seed=9)


def _grads(n=8, elems=512, seed=1234):
    rng = np.random.default_rng(seed)
    return [rng.standard_normal(elems).astype(np.float32) for _ in range(n)]


def _round(grads, **over):
    cfg = dict(topology="gradssharding", n_shards=4, schedule="pipelined",
               upload=UPLOAD, readahead_k=1, codec="identity")
    cfg.update(over)
    return FederatedSession(SessionConfig(**cfg)).round(grads)


def _survivor_mean(grads, result):
    return np.mean(np.stack([grads[i] for i in result.arrivals]),
                   axis=0).astype(np.float32)


# ---------------------------------------------------------------------------
# FaultModel streams
# ---------------------------------------------------------------------------

class TestFaultModelStreams:
    def test_participants_deterministic_and_seed_sensitive(self):
        fm = FaultModel(seed=3)
        a = fm.participants(20, 5, 8)
        assert a == fm.participants(20, 5, 8)
        assert a == tuple(sorted(a)) and len(set(a)) == 8
        assert all(0 <= i < 20 for i in a)
        others = {FaultModel(seed=s).participants(20, 5, 8)
                  for s in range(10)}
        assert len(others) > 1          # different seeds => different sets

    def test_participants_full_cohort_identity(self):
        assert FaultModel(seed=1).participants(6, 0, 6) == tuple(range(6))

    def test_dropout_and_stall_streams_independent(self):
        fm = FaultModel(dropout_rate=0.5, stall_rate=0.5, stall_s=2.0,
                        seed=4)
        drop = fm.dropout_plan(64, 1)
        assert np.array_equal(drop, fm.dropout_plan(64, 1))
        # stall stream must not perturb the dropout stream (separate keys)
        assert np.array_equal(
            drop, dataclasses.replace(fm, stall_rate=0.9).dropout_plan(64, 1))
        st_plan = fm.stall_plan(64, 1)
        assert set(np.unique(st_plan)) <= {0.0, 2.0}

    def test_failure_keyed_by_name_not_order(self):
        fm = FaultModel(failure_rate=0.5, seed=5)
        names = [f"r3-shard{j}" for j in range(32)]
        fates = [fm.failure(nm, 0) for nm in names]
        assert fates == [fm.failure(nm, 0) for nm in reversed(names)][::-1]
        assert any(fates) and not all(fates)

    def test_failure_capped_below_retry_budget(self):
        fm = FaultModel(failure_rate=1.0, seed=0)   # always-fail rate ...
        assert fm.failure("r0-x", 0) and fm.failure("r0-x", 1)
        assert not fm.failure("r0-x", 2)            # ... capped at 2 deaths

    def test_validation(self):
        with pytest.raises(ValueError, match="dropout_rate"):
            FaultModel(dropout_rate=1.5)
        with pytest.raises(ValueError, match="stall_s"):
            FaultModel(stall_s=-1.0)
        with pytest.raises(ValueError, match="max_failures"):
            FaultModel(max_failures=3)
        with pytest.raises(ValueError):
            FaultModel(seed=0).participants(4, 0, 5)

    def test_is_empty(self):
        assert FaultModel().is_empty
        assert not FAULTS.is_empty


class TestEnvResolution:
    def test_off_spellings(self, monkeypatch):
        for raw in ("", "off", "0", "false", "none"):
            monkeypatch.setenv("REPRO_AGG_FAULTS", raw)
            assert fault_model_from_env() is None
        monkeypatch.delenv("REPRO_AGG_FAULTS")
        assert fault_model_from_env() is None

    def test_on_and_rate(self, monkeypatch):
        monkeypatch.setenv("REPRO_AGG_FAULTS", "on")
        fm = fault_model_from_env(seed=2)
        assert fm is not None and not fm.is_empty and fm.seed == 2
        monkeypatch.setenv("REPRO_AGG_FAULTS", "0.35")
        fm = fault_model_from_env()
        assert fm.dropout_rate == fm.failure_rate == pytest.approx(0.35)
        monkeypatch.setenv("REPRO_AGG_FAULTS", "bogus")
        with pytest.raises(ValueError, match="REPRO_AGG_FAULTS"):
            fault_model_from_env()


# ---------------------------------------------------------------------------
# Knob validation
# ---------------------------------------------------------------------------

class TestKnobValidation:
    def test_participation_bounds(self):
        with pytest.raises(ValueError, match="participation_k"):
            validate_fault_knobs("pipelined", participation_k=0)
        with pytest.raises(ValueError, match="participation_k"):
            validate_fault_knobs("pipelined", participation_k=9, n_clients=8)

    def test_deadline_positive(self):
        with pytest.raises(ValueError, match="deadline_s"):
            validate_fault_knobs("pipelined", deadline_s=0.0)

    def test_quorum_schedule_coupling(self):
        with pytest.raises(ValueError, match="quorum"):
            validate_fault_knobs("pipelined", quorum=3)     # not quorum sched
        with pytest.raises(ValueError, match="quorum"):
            validate_fault_knobs("quorum")                  # knob missing
        with pytest.raises(ValueError, match="quorum"):
            validate_fault_knobs("quorum", quorum=9, n_clients=8)
        with pytest.raises(ValueError, match="quorum"):
            validate_fault_knobs("quorum", quorum=5, participation_k=4,
                                 n_clients=8)

    def test_faults_must_be_a_fault_model(self):
        with pytest.raises(TypeError, match="FaultModel"):
            validate_fault_knobs("pipelined", faults=FaultPlan())

    def test_session_validates_eagerly(self):
        with pytest.raises(ValueError, match="quorum"):
            FederatedSession(SessionConfig(schedule="barrier", quorum=3))
        with pytest.raises(ValueError, match="deadline_s"):
            FederatedSession(SessionConfig(deadline_s=-1.0))

    def test_session_rejects_two_fault_sources(self):
        with pytest.raises(ValueError, match="one"):
            FederatedSession(SessionConfig(faults=FAULTS), faults=FAULTS)
        with pytest.raises(ValueError, match="exactly one place"):
            rt = LambdaRuntime(faults=FaultPlan(fail={("r0-x", 0)}))
            run_round("gradssharding", _grads(4), rnd=0, store=ObjectStore(),
                      runtime=rt, faults=FAULTS, n_shards=2)

    def test_runtime_faultmodel_keyword_promotes_to_config(self):
        # a FaultModel passed via the faults= keyword must drive membership
        # (dropout/participation), not just runtime failures
        s = FederatedSession(topology="gradssharding", n_shards=2,
                             schedule="pipelined", upload=UPLOAD,
                             faults=FAULTS, participation_k=6)
        r = s.round(_grads())
        assert r.participants == FAULTS.participants(8, 0, 6)


# ---------------------------------------------------------------------------
# Zero-fault paths stay bit-identical
# ---------------------------------------------------------------------------

class TestZeroFaultNoOp:
    @pytest.mark.parametrize("schedule", ("barrier", "pipelined"))
    def test_empty_fault_model_is_invisible(self, schedule):
        grads = _grads()
        ref = _round(grads, schedule=schedule)
        r = _round(grads, schedule=schedule, faults=FaultModel(seed=99))
        assert np.array_equal(ref.avg_flat, r.avg_flat)
        assert ref.wall_clock_s == r.wall_clock_s
        assert ref.puts == r.puts and ref.gets == r.gets
        assert sum(x.billed_gb_s for x in ref.records) == \
            sum(x.billed_gb_s for x in r.records)
        assert r.delivered_fraction == 1.0 and r.retries == 0
        assert r.participants == tuple(range(8)) == r.arrivals

    def test_full_participation_k_is_invisible(self):
        grads = _grads()
        ref = _round(grads)
        r = _round(grads, participation_k=8)
        assert np.array_equal(ref.avg_flat, r.avg_flat)
        assert ref.wall_clock_s == r.wall_clock_s

    def test_loose_deadline_is_invisible(self):
        grads = _grads()
        ref = _round(grads)
        r = _round(grads, deadline_s=1e6)
        assert np.array_equal(ref.avg_flat, r.avg_flat)
        assert ref.wall_clock_s == r.wall_clock_s and r.late == ()

    def test_full_quorum_zero_jitter_matches_pipelined(self):
        # without upload jitter arrivals are index-ordered, so a full
        # quorum is exactly the pipelined round, bit for bit
        grads = _grads()
        ref = _round(grads, upload=None)
        r = _round(grads, upload=None, schedule="quorum", quorum=8)
        assert np.array_equal(ref.avg_flat, r.avg_flat)
        assert ref.wall_clock_s == r.wall_clock_s


# ---------------------------------------------------------------------------
# Faulty rounds: determinism + honest accounting
# ---------------------------------------------------------------------------

class TestFaultyRoundDeterminism:
    @pytest.mark.parametrize("topology", TOPOLOGIES)
    def test_same_seed_identical_everything(self, topology):
        grads = _grads()
        opts = dict(topology=topology, faults=FAULTS, participation_k=6)
        if topology not in ("gradssharding", "sharded_tree"):
            opts.pop("n_shards", None)
        runs = [_round(grads, **opts) for _ in range(2)]
        a, b = runs
        assert a.participants == b.participants
        assert a.dropped == b.dropped and a.arrivals == b.arrivals
        assert np.array_equal(a.avg_flat, b.avg_flat)
        assert a.wall_clock_s == b.wall_clock_s
        assert a.retries == b.retries
        assert a.delivered_fraction == b.delivered_fraction
        # full retry timeline replays: (name, attempt, start, end, failed)
        tl = lambda r: [(x.fn_name, x.attempt, x.start_s, x.end_s, x.failed)
                        for x in r.records]
        assert tl(a) == tl(b)

    def test_engines_bit_identical_under_faults(self):
        grads = _grads()
        avgs = {_round(grads, engine=e, faults=FAULTS, participation_k=6)
                .avg_flat.tobytes() for e in ENGINES}
        assert len(avgs) == 1

    def test_different_seeds_different_participants(self):
        grads = _grads(n=16)
        seen = {_round(grads, faults=FaultModel(seed=s),
                       participation_k=8).participants for s in range(8)}
        assert len(seen) > 1

    def test_faulty_average_is_survivor_mean(self):
        grads = _grads()
        r = _round(grads, faults=FAULTS, participation_k=6)
        assert 0.0 < r.delivered_fraction <= 1.0
        assert set(r.dropped).isdisjoint(r.arrivals)
        np.testing.assert_allclose(r.avg_flat, _survivor_mean(grads, r),
                                   rtol=1e-6)

    def test_retries_bill_and_backoff_delays(self):
        grads = _grads()
        fm = dataclasses.replace(FAULTS, dropout_rate=0.0, stall_rate=0.0)
        r = _round(grads, faults=fm)
        assert r.retries > 0            # seed 9 injects failures
        failed = [x for x in r.records if x.failed]
        assert all(x.billed_gb_s > 0.0 for x in failed)
        # the retry relaunches after the death plus the backoff wait
        for f in failed:
            nxt = next(x for x in r.records
                       if x.fn_name == f.fn_name
                       and x.attempt == f.attempt + 1)
            assert nxt.start_s == pytest.approx(
                f.end_s + fm.retry_backoff_s * 2.0 ** f.attempt)
            assert nxt.cold_start   # the crash evicted the warm container

    def test_all_dropped_raises(self):
        grads = _grads(4)
        with pytest.raises(RuntimeError, match="no active participants"):
            _round(grads, faults=FaultModel(dropout_rate=1.0, seed=1))


class TestDeadline:
    def test_deadline_excludes_stragglers(self):
        grads = _grads()
        r = _round(grads, faults=FAULTS, deadline_s=4.0)
        assert r.late != ()                      # seed 9 stalls stragglers
        assert set(r.late).isdisjoint(r.arrivals)
        assert r.delivered_fraction < 1.0
        np.testing.assert_allclose(r.avg_flat, _survivor_mean(grads, r),
                                   rtol=1e-6)
        # a cut round is only known complete at the deadline
        assert r.wall_clock_s >= 4.0

    def test_deadline_alone_preserves_index_fold_order(self):
        grads = _grads()
        r = _round(grads, faults=FAULTS, deadline_s=4.0)
        assert list(r.arrivals) == sorted(r.arrivals)

    def test_impossible_deadline_raises(self):
        grads = _grads()
        with pytest.raises(RuntimeError, match="deadline"):
            _round(grads, deadline_s=1e-9)

    @pytest.mark.parametrize("schedule", ("barrier", "pipelined"))
    def test_deadline_deterministic_across_schedules(self, schedule):
        grads = _grads()
        a = _round(grads, schedule=schedule, faults=FAULTS, deadline_s=4.0)
        b = _round(grads, schedule=schedule, faults=FAULTS, deadline_s=4.0)
        assert np.array_equal(a.avg_flat, b.avg_flat)
        assert a.late == b.late and a.wall_clock_s == b.wall_clock_s


class TestQuorum:
    def test_quorum_takes_first_q_arrivals(self):
        grads = _grads()
        r = _round(grads, schedule="quorum", quorum=5)
        assert len(r.arrivals) == 5
        assert r.delivered_fraction == pytest.approx(5 / 8)
        np.testing.assert_allclose(r.avg_flat, _survivor_mean(grads, r),
                                   rtol=1e-6)

    def test_quorum_folds_in_arrival_order(self):
        # jittered starts: arrival order is the upload-completion order,
        # not index order — and it replays identically
        grads = _grads()
        r = _round(grads, schedule="quorum", quorum=5)
        r2 = _round(grads, schedule="quorum", quorum=5)
        assert r.arrivals == r2.arrivals
        assert len(set(r.arrivals)) == 5
        assert list(r.arrivals) != sorted(r.arrivals)   # UPLOAD jitter bites

    def test_quorum_composes_with_faults(self):
        grads = _grads()
        r = _round(grads, schedule="quorum", quorum=3, faults=FAULTS,
                   participation_k=6)
        assert len(r.arrivals) == 3
        assert set(r.arrivals) <= set(r.participants)
        np.testing.assert_allclose(r.avg_flat, _survivor_mean(grads, r),
                                   rtol=1e-6)

    def test_quorum_engine_bit_identity(self):
        grads = _grads()
        avgs = {_round(grads, schedule="quorum", quorum=5, engine=e)
                .avg_flat.tobytes() for e in ENGINES}
        assert len(avgs) == 1


# ---------------------------------------------------------------------------
# Multi-round sessions under faults
# ---------------------------------------------------------------------------

class TestFaultySessions:
    def test_session_survives_and_varies_per_round(self):
        grads = _grads()
        s = FederatedSession(SessionConfig(
            topology="gradssharding", n_shards=4, schedule="pipelined",
            upload=UPLOAD, codec="identity", faults=FAULTS,
            participation_k=6))
        results = list(s.run(lambda rnd: grads, rounds=4))
        assert len(results) == 4
        assert len({r.participants for r in results}) > 1   # per-round draw
        for r in results:
            np.testing.assert_allclose(
                r.avg_flat, _survivor_mean(grads, r), rtol=1e-6)

    def test_ambient_env_matrix(self):
        # the CI fault matrix job (REPRO_AGG_FAULTS=on) widens this test:
        # with the env set these rounds run under the canonical nonzero
        # model; unset, they assert the fault-free invariants instead
        fm = fault_model_from_env(seed=3)
        grads = _grads()
        a = _round(grads, faults=fm, participation_k=6)
        b = _round(grads, faults=fm, participation_k=6)
        assert np.array_equal(a.avg_flat, b.avg_flat)
        assert a.participants == b.participants and a.retries == b.retries
        np.testing.assert_allclose(a.avg_flat, _survivor_mean(grads, a),
                                   rtol=1e-6)
        if fm is None:
            assert a.delivered_fraction == 1.0 and a.retries == 0

    def test_env_model_round_trips_through_session(self, monkeypatch):
        monkeypatch.setenv("REPRO_AGG_FAULTS", "on")
        fm = fault_model_from_env(seed=5)
        grads = _grads()
        a = _round(grads, faults=fm, participation_k=6)
        b = _round(grads, faults=fm, participation_k=6)
        assert np.array_equal(a.avg_flat, b.avg_flat)
        assert a.retries == b.retries


# ---------------------------------------------------------------------------
# Analytical fault model (cost_model counterparts)
# ---------------------------------------------------------------------------

class TestFaultAnalytics:
    def test_expected_attempts(self):
        assert cm.expected_attempts(0.0) == 1.0
        assert cm.expected_attempts(0.5) == pytest.approx(1 + 0.5 + 0.25)
        with pytest.raises(ValueError):
            cm.expected_attempts(1.0)

    def test_expected_retry_delay_monotone(self):
        lim = cm.LambdaLimits()
        assert cm.expected_retry_delay_s(0.0, lim) == 0.0
        d1 = cm.expected_retry_delay_s(0.2, lim)
        d2 = cm.expected_retry_delay_s(0.4, lim)
        assert 0.0 < d1 < d2
        assert cm.expected_retry_delay_s(0.2, lim, backoff_s=1.0) > d1

    def test_expected_retry_gb_s_scales_with_memory(self):
        lim = cm.LambdaLimits()
        assert cm.expected_retry_gb_s(1024, 0.0, lim) == 0.0
        assert cm.expected_retry_gb_s(2048, 0.3, lim) == pytest.approx(
            2 * cm.expected_retry_gb_s(1024, 0.3, lim))

    def test_expected_deliveries(self):
        assert cm.expected_deliveries(8) == 8.0
        assert cm.expected_deliveries(8, 6, 0.25) == pytest.approx(4.5)
        with pytest.raises(ValueError):
            cm.expected_deliveries(8, 9)


# ---------------------------------------------------------------------------
# Property: partial-participation average == plain mean over survivors
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2**16), n=st.integers(2, 10),
       dropout=st.floats(0.0, 0.6),
       engine=st.sampled_from(ENGINES))
def test_property_survivor_mean(seed, n, dropout, engine):
    grads = _grads(n=n, elems=64, seed=seed)
    fm = FaultModel(dropout_rate=dropout, seed=seed)
    try:
        r = run_round("gradssharding", grads, rnd=0, store=ObjectStore(),
                      runtime=LambdaRuntime(), engine=engine,
                      schedule="pipelined", upload=UPLOAD, faults=fm,
                      codec="identity", n_shards=2)
    except RuntimeError:
        # every participant dropped — the documented failure mode
        assert fm.dropout_plan(n, 0).all()
        return
    survivors = [grads[i] for i in r.arrivals]
    assert len(survivors) == round(r.delivered_fraction * n)
    np.testing.assert_allclose(
        r.avg_flat,
        np.mean(np.stack(survivors), axis=0).astype(np.float32), rtol=1e-5)
