"""Discrete-event core: heap ordering, deterministic tie-breaking, per-entity
timelines, availability publication."""
import pytest

from repro.serverless.event_sim import AvailabilityMap, EventSim, Timeline


def test_events_fire_in_time_order():
    sim = EventSim()
    log = []
    sim.at(3.0, log.append, "c")
    sim.at(1.0, log.append, "a")
    sim.at(2.0, log.append, "b")
    sim.run()
    assert log == ["a", "b", "c"]
    assert sim.now == 3.0
    assert sim.fired == 3


def test_tie_break_is_schedule_order_then_priority():
    sim = EventSim()
    log = []
    sim.at(1.0, log.append, "first")
    sim.at(1.0, log.append, "second")
    sim.at(1.0, log.append, "prio", priority=-1)   # lower priority fires first
    sim.run()
    assert log == ["prio", "first", "second"]


def test_run_until_leaves_later_events_pending():
    sim = EventSim()
    log = []
    sim.at(1.0, log.append, 1)
    sim.at(5.0, log.append, 5)
    sim.run(until=2.0)
    assert log == [1] and len(sim) == 1
    sim.run()
    assert log == [1, 5]


def test_drain_fires_everything_without_moving_cursor():
    sim = EventSim()
    sim.advance_to(2.0)
    log = []
    sim.at(10.0, log.append, "late")
    sim.at(0.5, log.append, "early")               # may predate the cursor
    n = sim.drain()
    assert n == 2 and log == ["early", "late"]
    assert sim.now == 2.0                           # cursor untouched
    assert len(sim) == 0


def test_after_and_advance_to_monotone():
    sim = EventSim()
    sim.advance_to(4.0)
    sim.advance_to(1.0)                             # no-op backwards
    assert sim.now == 4.0
    ev = sim.after(2.5)
    assert ev.time == 6.5


def test_timeline_advance_and_stall():
    tl = Timeline(10.0)
    assert tl.advance(2.0) == 12.0
    assert tl.wait_until(11.0) == 0.0               # already past
    assert tl.t == 12.0
    assert tl.wait_until(15.0) == pytest.approx(3.0)
    assert tl.t == 15.0


def test_availability_first_write_wins():
    av = AvailabilityMap()
    assert not av.known("k")
    assert av.time_of("k") == 0.0                   # default: always available
    assert av.time_of("k", default=7.0) == 7.0
    av.publish("k", 5.0)
    av.publish("k", 9.0)                            # later publish ignored
    assert av.time_of("k") == 5.0
    av.publish("k", 3.0)                            # earlier one wins
    assert av.time_of("k") == 3.0


def test_sim_reset():
    sim = EventSim()
    sim.at(1.0, lambda: None)
    sim.run()
    sim.reset()
    assert sim.now == 0.0 and len(sim) == 0 and sim.fired == 0
