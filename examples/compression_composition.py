"""Per-shard gradient compression composed with GradsSharding (paper §VI:
"compression ... can be composed by compressing each shard before upload").

The wire format is a first-class session knob now: ``SessionConfig(codec=
...)`` makes clients PUT codec-encoded shards, the store/op-log/billing see
wire bytes, and aggregators decode-before-fold — no ad-hoc kernel calls.
For each registered codec the example reports bytes-on-the-wire, modeled
round wall-clock, billed GB-s, and the per-round ``codec_error`` the
session surfaces (max-abs vs the uncompressed reference).

Run:  PYTHONPATH=src python examples/compression_composition.py \
          [--topology gradssharding --clients 8 --shards 4 --size 200000]
"""
import argparse

import numpy as np

from repro.api import FederatedSession, SessionConfig
from repro.core.cost_model import UploadModel
from repro.core.wire_codec import available_codecs, get_codec

MB = 1e6


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--topology", default="gradssharding",
                    choices=["gradssharding", "lambda_fl", "lifl",
                             "sharded_tree"])
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--size", type=int, default=200_000)
    ap.add_argument("--upload-mbps", type=float, default=16.0)
    args = ap.parse_args(argv)

    rng = np.random.default_rng(0)
    grads = [rng.standard_normal(args.size).astype(np.float32)
             for _ in range(args.clients)]
    upload = UploadModel(mbps=args.upload_mbps)
    raw_upload_bytes = args.clients * args.size * 4

    print(f"{args.topology}, N={args.clients}, M={args.shards}, "
          f"|g|={args.size * 4 / MB:.2f} MB/client, "
          f"codecs: {', '.join(available_codecs())}\n")
    base_wall = None
    for codec in ("identity", "fp16", "qsgd8", "topk"):
        session = FederatedSession(SessionConfig(
            topology=args.topology, n_shards=args.shards,
            schedule="pipelined", upload=upload, codec=codec))
        r = session.round(grads)
        # client-upload wire volume: every PUT of the round minus the
        # aggregator outputs (which stay raw f32)
        out_bytes = sum(nb for key, nb in session.store.stats.put_log
                        if "/avg/" in key or "/partial/" in key)
        wire = session.store.stats.bytes_written - out_bytes
        billed = sum(rec.billed_gb_s for rec in r.records)
        if base_wall is None:
            base_wall = r.wall_clock_s
        print(f"{codec:9s}: wire {wire / MB:7.2f} MB "
              f"(vs {raw_upload_bytes / MB:.2f} MB raw, "
              f"{raw_upload_bytes / wire:4.1f}x smaller)  "
              f"wall {r.wall_clock_s:6.2f}s "
              f"({base_wall / r.wall_clock_s:.2f}x)  "
              f"billed {billed:.3f} GB-s  "
              f"codec_error {r.codec_error:.2e}")
        assert r.codec == get_codec(codec).name

    print("\nS3-transfer implication (paper: I/O is >90% of time & the "
          "dominant cost): 4x fewer bytes ≈ 4x faster aggregation reads "
          "and proportionally lower Lambda GB-s on the transfer-bound "
          "path — and codec_error makes the accuracy cost observable "
          "instead of silent.")


if __name__ == "__main__":
    main()
