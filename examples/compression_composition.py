"""Per-shard gradient compression composed with GradsSharding (paper §VI:
"compression ... can be composed by compressing each shard before upload").

Each client QSGD-int8-quantizes (or top-k-sparsifies) every shard with the
Pallas kernels before the PUT; aggregators average dequantized shards. The
example reports bytes-on-the-wire reduction and the aggregation error it
introduces vs the exact pipeline.

Run:  PYTHONPATH=src python examples/compression_composition.py
"""
import numpy as np

import jax.numpy as jnp

from repro.core.sharding import make_plan, reconstruct, shard
from repro.kernels import ops

N, M, SIZE = 8, 4, 200_000


def main():
    rng = np.random.default_rng(0)
    grads = [rng.standard_normal(SIZE).astype(np.float32) for _ in range(N)]
    plan = make_plan("uniform", SIZE, M)
    exact = np.stack(grads).mean(axis=0)

    for mode in ("qsgd8", "topk1%"):
        raw_bytes = comp_bytes = 0
        avg_shards = []
        for j in range(M):
            decoded = []
            for g in grads:
                sh = shard(g, plan)[j]
                raw_bytes += sh.nbytes
                if mode == "qsgd8":
                    codes, scales, l = ops.qsgd_compress(jnp.asarray(sh))
                    comp_bytes += codes.nbytes + scales.nbytes
                    decoded.append(np.asarray(
                        ops.qsgd_decompress(codes, scales, l)))
                else:
                    k = max(1, (32 * 128) // 100)     # top 1% per tile
                    sp = ops.topk_sparsify(jnp.asarray(sh), k)
                    nnz = int(jnp.sum(sp != 0))
                    comp_bytes += nnz * 8             # value+index pairs
                    decoded.append(np.asarray(sp))
            acc = decoded[0].copy()
            for d in decoded[1:]:
                acc += d
            avg_shards.append(acc / N)
        got = reconstruct(avg_shards, plan)
        rel = np.linalg.norm(got - exact) / np.linalg.norm(exact)
        print(f"{mode:7s}: wire bytes {comp_bytes/1e6:7.2f} MB "
              f"(vs {raw_bytes/1e6:.2f} MB raw, "
              f"{raw_bytes/comp_bytes:.1f}x smaller), "
              f"aggregate rel-err {rel:.4f}")

    print("\nS3-transfer implication (paper: I/O is >90% of time & the "
          "dominant cost): 4x fewer bytes ≈ 4x faster aggregation reads "
          "and 4x lower Lambda GB-s on the transfer-bound path.")


if __name__ == "__main__":
    main()
