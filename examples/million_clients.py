"""Million-client rounds on a laptop: the lazy population engine.

A :class:`~repro.serverless.population.ClientPopulation` replaces the
eager list of N gradient arrays: schedules, faults and participation are
drawn lazily per cohort-index range (same seeded streams as the eager
path — results are bit-identical), and only the O(active aggregators)
slice of client state is ever materialized. Hand it to ``SessionConfig
.population`` and ``session.round()`` takes no gradients at all —
N = 10⁶ rounds fit in well under a GB of host memory.

The walkthrough runs one round per architecture at growing cohort sizes
and prints the cost-crossover table: single-tier λ-FL is cheapest while
one function can swallow the fan-in; the hierarchical ``geo_tiered``
topology catches up as edge aggregation amortizes the long-haul bytes;
GradsSharding pays M-way shard traffic for its O(|θ|/M) memory ceiling,
which client count alone never threatens.

Run:  PYTHONPATH=src python examples/million_clients.py [--million]
"""
import argparse
import dataclasses
import resource
import time

from repro import FederatedSession, SessionConfig
from repro.core.cost_model import UploadModel
from repro.serverless.population import ClientPopulation
from repro.serverless.runtime import DEFAULT_LIMITS

TOPOLOGIES = ("lambda_fl", "geo_tiered", "gradssharding")
GRAD_ELEMS = 4_096
UPLOAD = UploadModel(mbps=16.0, jitter_s=3.0, rate_jitter=0.5,
                     compute_s=2.0, compute_jitter=1.0, seed=11)


def one_round(topology: str, n: int):
    session = FederatedSession(SessionConfig(
        topology=topology,
        population=ClientPopulation(n, grad_elems=GRAD_ELEMS, seed=1),
        upload=UPLOAD,
        schedule="pipelined", readahead_k=4,
        # bounded-memory hygiene at cohort scale: skip the per-op store
        # log and per-round record retention...
        log_ops=False, keep_records=False,
        # ...and price (rather than refuse) fan-ins that overrun the
        # Lambda timeout — feasibility walls are a separate study
        limits=dataclasses.replace(DEFAULT_LIMITS,
                                   max_timeout_s=10_000_000),
        track_codec_error=False))
    t0 = time.perf_counter()
    r = session.round()
    return r, session.total_cost(), time.perf_counter() - t0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--million", action="store_true",
                    help="include the N=10^6 cells (~2 min host time)")
    args = ap.parse_args(argv)
    ns = (1_000, 10_000, 100_000) + ((1_000_000,) if args.million else ())

    cells = {}
    for n in ns:
        for topology in TOPOLOGIES:
            r, cost, host_s = one_round(topology, n)
            cells[n, topology] = (r.wall_clock_s, cost)
            rss_mb = resource.getrusage(
                resource.RUSAGE_SELF).ru_maxrss / 1024
            print(f"N={n:>9,} {topology:14s}: wall {r.wall_clock_s:8.1f}s"
                  f"  ${cost:.4f}/round  ({cost / n * 1e6:6.2f} µ$/client)"
                  f"  [host {host_s:5.1f}s, rss {rss_mb:4.0f} MB]")

    print("\ncheapest architecture by cohort size:")
    for n in ns:
        best = min(TOPOLOGIES, key=lambda t: cells[n, t][1])
        wall, cost = cells[n, best]
        print(f"  N={n:>9,}: {best:14s} ${cost:.4f}/round, "
              f"wall {wall:.1f}s")
    if not args.million:
        print("\n(re-run with --million for the N=10^6 cells)")


if __name__ == "__main__":
    main()
