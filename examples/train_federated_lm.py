"""End-to-end driver: federated training of a transformer LM through the
serverless GradsSharding aggregation substrate.

N clients each hold a non-IID synthetic Markov token stream; every round
they train locally (SGD+momentum, the paper's client optimizer), upload
gradient-shards to the object store, M Lambda aggregators average them,
and clients reconstruct + apply the update. Loss decreases; swapping
``--topology`` changes only cost/latency, never the learning trajectory.

Run:  PYTHONPATH=src python examples/train_federated_lm.py \
          --rounds 10 --clients 4 --shards 4 --topology gradssharding
"""
import argparse
import dataclasses
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import aggregation as agg
from repro.core.fedavg import apply_delta, local_sgd_update, model_delta
from repro.core.sharding import flatten, unflatten
from repro.data import SyntheticLM
from repro.models import registry as models
from repro.serverless import LambdaRuntime
from repro.store import ObjectStore


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--local_steps", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--topology", default="gradssharding",
                    choices=["gradssharding", "lambda_fl", "lifl"])
    ap.add_argument("--partition", default="uniform",
                    choices=["uniform", "balanced", "layer_contiguous"])
    args = ap.parse_args(argv)

    cfg = dataclasses.replace(get_arch(args.arch).smoke, vocab=256,
                              remat=False)
    params = models.init_params(jax.random.PRNGKey(0), cfg)
    data = SyntheticLM(vocab=256, seq_len=args.seq, seed=0,
                       markov_concentration=0.4)
    store, runtime = LambdaRuntime(), None
    store, runtime = ObjectStore(), LambdaRuntime()

    def loss_fn(p, b):
        return models.loss_fn(p, cfg, b)

    _, spec = flatten(params)
    tensor_sizes = None
    if args.partition != "uniform":
        from repro.core.sharding import flatten as _fl
        f, sp = _fl(params)
        tensor_sizes = list(sp.sizes)

    print(f"federated {args.arch} ({models.param_count(cfg):,} params), "
          f"N={args.clients} clients, topology={args.topology} "
          f"M={args.shards}")
    t0 = time.time()
    for rnd in range(args.rounds):
        flats = []
        losses = []
        for c in range(args.clients):
            local = params
            vel = None
            for s in range(args.local_steps):
                batch = data.batch(c, rnd * args.local_steps + s,
                                   args.batch)
                local, vel, l = local_sgd_update(loss_fn, local, batch,
                                                 lr=args.lr, momentum=0.9)
            losses.append(float(l))
            f, spec = flatten(model_delta(params, local))
            flats.append(np.asarray(f))
        res = agg.aggregate_round(
            args.topology, flats, rnd=rnd, store=store, runtime=runtime,
            n_shards=args.shards, partition=args.partition,
            tensor_sizes=tensor_sizes)
        params = apply_delta(params, unflatten(jnp.asarray(res.avg_flat),
                                               spec))
        print(f"round {rnd:3d}  client-loss {np.mean(losses):.4f}  "
              f"agg-wall {res.wall_clock_s:.2f}s  "
              f"ops {res.puts}P/{res.gets}G  "
              f"peak-mem {res.peak_memory_mb:.0f}MB")
    print(f"total lambda cost: ${runtime.total_cost():.6f}  "
          f"({time.time()-t0:.1f}s real)")


if __name__ == "__main__":
    main()
