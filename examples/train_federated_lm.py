"""End-to-end driver: federated training of a transformer LM through the
serverless GradsSharding aggregation substrate.

N clients each hold a non-IID synthetic Markov token stream; every round
they train locally (SGD+momentum, the paper's client optimizer), upload
gradient-shards to the object store, M Lambda aggregators average them,
and clients reconstruct + apply the update. Loss decreases; swapping
``--topology`` changes only cost/latency, never the learning trajectory —
and so does swapping ``--schedule``: the pipelined schedule overlaps
client uploads with streaming shard folds (and round r+1 uploads with
round r read-back), cutting modeled wall-clock while ``avg_flat`` stays
bit-identical to the barrier schedule.

Run:  PYTHONPATH=src python examples/train_federated_lm.py \
          --rounds 10 --clients 4 --shards 4 --topology gradssharding \
          --schedule pipelined --upload-mbps 16 --jitter-s 2
"""
import argparse
import dataclasses
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.api import FederatedSession, SessionConfig
from repro.configs import get_arch
from repro.core.cost_model import UploadModel
from repro.core.fedavg import apply_delta, local_sgd_update, model_delta
from repro.core.sharding import flatten, unflatten
from repro.data import SyntheticLM
from repro.models import registry as models


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--local_steps", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--topology", default="gradssharding",
                    choices=["gradssharding", "lambda_fl", "lifl",
                             "sharded_tree"])
    ap.add_argument("--partition", default="uniform",
                    choices=["uniform", "balanced", "layer_contiguous"])
    ap.add_argument("--schedule", default=None,
                    choices=["barrier", "pipelined"],
                    help="round schedule (default: REPRO_AGG_SCHEDULE / "
                         "barrier)")
    ap.add_argument("--engine", default=None,
                    choices=["streaming", "batched", "incremental"])
    ap.add_argument("--readahead-k", type=int, default=None,
                    help="pipelined read-ahead window: GET up to k "
                         "contributions ahead of the fold frontier "
                         "(default: REPRO_AGG_READAHEAD / 1); fold order "
                         "and the learning trajectory never change")
    ap.add_argument("--codec", default=None,
                    choices=["identity", "fp16", "qsgd8", "topk"],
                    help="wire codec for client uploads (default: "
                         "REPRO_AGG_CODEC / identity); lossy codecs cut "
                         "upload bytes/GET time and report per-round "
                         "codec_error")
    ap.add_argument("--upload-mbps", type=float, default=None,
                    help="per-client uplink MB/s (None = instantaneous)")
    ap.add_argument("--download-mbps", type=float, default=None)
    ap.add_argument("--jitter-s", type=float, default=0.0,
                    help="max per-client upload start jitter (seconds)")
    ap.add_argument("--rate-jitter", type=float, default=0.0)
    ap.add_argument("--local-compute-s", type=float, default=0.0,
                    help="modeled per-client local training time per round "
                         "(pipelined sessions overlap it with read-back)")
    args = ap.parse_args(argv)

    cfg = dataclasses.replace(get_arch(args.arch).smoke, vocab=256,
                              remat=False)
    params = models.init_params(jax.random.PRNGKey(0), cfg)
    data = SyntheticLM(vocab=256, seq_len=args.seq, seed=0,
                      markov_concentration=0.4)

    def loss_fn(p, b):
        return models.loss_fn(p, cfg, b)

    tensor_sizes = None
    if args.partition != "uniform":
        _, sp = flatten(params)
        tensor_sizes = list(sp.sizes)

    upload = None
    if args.upload_mbps or args.download_mbps or args.jitter_s \
            or args.rate_jitter or args.local_compute_s:
        upload = UploadModel(mbps=args.upload_mbps,
                             download_mbps=args.download_mbps,
                             jitter_s=args.jitter_s,
                             rate_jitter=args.rate_jitter,
                             compute_s=args.local_compute_s)

    state = {"params": params, "spec": None, "losses": []}

    def client_grads(rnd):
        flats, losses = [], []
        for c in range(args.clients):
            local, vel, l = state["params"], None, 0.0
            for s in range(args.local_steps):
                batch = data.batch(c, rnd * args.local_steps + s, args.batch)
                local, vel, l = local_sgd_update(loss_fn, local, batch,
                                                 lr=args.lr, momentum=0.9,
                                                 velocity=vel)
            losses.append(float(l))
            f, state["spec"] = flatten(model_delta(state["params"], local))
            flats.append(np.asarray(f))
        state["losses"] = losses
        return flats

    def on_round(rnd, res):
        state["params"] = apply_delta(
            state["params"], unflatten(jnp.asarray(res.avg_flat),
                                       state["spec"]))
        codec = "" if res.codec == "identity" \
            else f" {res.codec} err={res.codec_error:.1e}"
        print(f"round {rnd:3d}  client-loss {np.mean(state['losses']):.4f}  "
              f"agg-wall {res.wall_clock_s:.2f}s  "
              f"ops {res.puts}P/{res.gets}G  "
              f"peak-mem {res.peak_memory_mb:.0f}MB  "
              f"[{res.schedule}{codec}]")

    print(f"federated {args.arch} ({models.param_count(cfg):,} params), "
          f"N={args.clients} clients, topology={args.topology} "
          f"M={args.shards}, schedule={args.schedule or 'barrier'}")
    t0 = time.time()
    session = FederatedSession(SessionConfig(
        topology=args.topology, n_shards=args.shards,
        partition=args.partition, tensor_sizes=tensor_sizes,
        engine=args.engine, schedule=args.schedule,
        readahead_k=args.readahead_k, codec=args.codec, upload=upload))
    for rnd, res in enumerate(session.run(client_grads, args.rounds)):
        on_round(rnd, res)
    print(f"session wall (modeled): {session.session_wall_s:.2f}s  "
          f"vs sum-of-round-walls {session.sum_round_walls_s:.2f}s")
    print(f"total lambda cost: ${session.lambda_cost():.6f}  "
          f"({time.time()-t0:.1f}s real)")


if __name__ == "__main__":
    main()
