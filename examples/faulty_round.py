"""A round that survives faults: dropout, a stalled upload, a retried
aggregator — and still delivers the exact mean over the survivors.

The seeded :class:`~repro.serverless.faults.FaultModel` drives every
disturbance: ~10% of the sampled participants drop out before uploading,
some uploads stall, and aggregator invocations die at launch with the
configured probability (the runtime retries with exponential backoff and
idempotent first-write-wins PUTs, so a retried round is still correct).
The result reports the degradation honestly: ``delivered_fraction``,
``dropped``/``late``, ``retries`` — and ``avg_flat`` equals the plain
mean over the arrivals' gradients, on every engine.

Run:  PYTHONPATH=src python examples/faulty_round.py \
          [--seed 9 --schedule pipelined --deadline-s 8 --quorum 12]
"""
import argparse

import numpy as np

from repro import FederatedSession, SessionConfig
from repro.core import cost_model as cm
from repro.core.cost_model import UploadModel
from repro.serverless.faults import FaultModel

N_CLIENTS, M, GRAD_SIZE = 20, 4, 50_000


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--seed", type=int, default=9,
                    help="FaultModel seed (every disturbance stream is "
                         "deterministic given the seed and round)")
    ap.add_argument("--schedule", default="pipelined",
                    choices=["barrier", "pipelined", "quorum"])
    ap.add_argument("--participation-k", type=int, default=16,
                    help="sample K of the 20-client cohort per round")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="aggregate whatever landed by T (cuts stragglers)")
    ap.add_argument("--quorum", type=int, default=None,
                    help="with --schedule quorum: fold fires on the q-th "
                         "arrival, in arrival order (semi-async FedBuff)")
    ap.add_argument("--rounds", type=int, default=3)
    args = ap.parse_args(argv)
    if args.schedule == "quorum" and args.quorum is None:
        args.quorum = 12

    faults = FaultModel(dropout_rate=0.10, stall_rate=0.15, stall_s=6.0,
                        failure_rate=0.30, retry_backoff_s=0.5,
                        seed=args.seed)
    session = FederatedSession(SessionConfig(
        topology="gradssharding", n_shards=M, schedule=args.schedule,
        upload=UploadModel(mbps=16.0, jitter_s=3.0, rate_jitter=0.5,
                           seed=11),
        faults=faults, participation_k=args.participation_k,
        deadline_s=args.deadline_s, quorum=args.quorum))

    rng = np.random.default_rng(0)
    grads = [rng.standard_normal(GRAD_SIZE).astype(np.float32)
             for _ in range(N_CLIENTS)]

    print(f"cohort N={N_CLIENTS}, K={args.participation_k} sampled/round, "
          f"schedule={args.schedule}, fault seed={args.seed}")
    e_deliver = cm.expected_deliveries(N_CLIENTS, args.participation_k,
                                       faults.dropout_rate)
    print(f"expected deliveries/round: {e_deliver:.1f}, "
          f"expected attempts/invocation: "
          f"{cm.expected_attempts(faults.failure_rate):.3f}\n")

    for r in session.run(lambda rnd: grads, rounds=args.rounds):
        survivors = np.mean(np.stack([grads[i] for i in r.arrivals]),
                            axis=0).astype(np.float32)
        exact = np.allclose(r.avg_flat, survivors, rtol=1e-6)
        rnd = session.rounds_run - 1
        print(f"round {rnd}: delivered {len(r.arrivals)}/"
              f"{len(r.participants)} "
              f"({r.delivered_fraction:.0%}), dropped={list(r.dropped)}, "
              f"late={list(r.late)}, retries={r.retries}, "
              f"wall={r.wall_clock_s:.2f}s, survivor-mean exact: {exact}")
        assert exact

    print(f"\nsession: wall={session.session_wall_s:.2f}s, "
          f"total cost=${session.total_cost():.6f} "
          f"(lambda ${session.lambda_cost():.6f} + "
          f"s3 ${session.s3_cost():.6f})")
    print("every failed attempt was retried and billed; the averages "
          "above are bit-exact over each round's survivors.")


if __name__ == "__main__":
    main()
