"""A round that survives faults: dropout, a stalled upload, a retried
aggregator — and still delivers the exact mean over the survivors.

The seeded :class:`~repro.serverless.faults.FaultModel` drives every
disturbance: ~10% of the sampled participants drop out before uploading,
some uploads stall, and aggregator invocations die at launch with the
configured probability (the runtime retries with exponential backoff and
idempotent first-write-wins PUTs, so a retried round is still correct).
The result reports the degradation honestly: ``delivered_fraction``,
``dropped``/``late``, ``retries`` — and ``avg_flat`` equals the plain
mean over the arrivals' gradients, on every engine.

Robustness knobs layered on top:

* ``--staleness-policy`` keeps a cut straggler's upload in the session's
  :class:`~repro.serverless.faults.StaleBuffer` and folds it into a later
  round with a staleness weight (``--staleness-alpha`` tunes the
  polynomial 1/(1+s)^alpha decay; the demo shows a round-r casualty's
  gradient landing, weighted, in round r+2). When stale gradients fold,
  the reported average is the *weighted* survivor mean.
* ``--hedge`` races a speculative replica against any aggregator whose
  retry chain overruns ``hedge_factor`` x its fault-free expected finish
  — first finisher wins, the loser stays billed.

Run:  PYTHONPATH=src python examples/faulty_round.py \
          [--seed 9 --schedule pipelined --deadline-s 8 --quorum 12]
          [--staleness-policy polynomial --staleness-alpha 0.5 --hedge 1.2]
"""
import argparse

import numpy as np

from repro import FederatedSession, SessionConfig
from repro.core import cost_model as cm
from repro.core.cost_model import UploadModel
from repro.serverless.faults import FaultModel, StalenessPolicy

N_CLIENTS, M, GRAD_SIZE = 20, 4, 50_000


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--seed", type=int, default=9,
                    help="FaultModel seed (every disturbance stream is "
                         "deterministic given the seed and round)")
    ap.add_argument("--schedule", default="pipelined",
                    choices=["barrier", "pipelined", "quorum"])
    ap.add_argument("--participation-k", type=int, default=16,
                    help="sample K of the 20-client cohort per round")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="aggregate whatever landed by T (cuts stragglers)")
    ap.add_argument("--quorum", type=int, default=None,
                    help="with --schedule quorum: fold fires on the q-th "
                         "arrival, in arrival order (semi-async FedBuff)")
    ap.add_argument("--staleness-policy", default=None,
                    choices=["constant", "polynomial", "cutoff"],
                    help="fold cut stragglers' buffered uploads into later "
                         "rounds with this staleness weighting")
    ap.add_argument("--staleness-alpha", type=float, default=0.5,
                    help="polynomial decay exponent: weight 1/(1+s)^alpha")
    ap.add_argument("--reentry-delay-s", type=float, default=None,
                    help="extra delay before a dropped client's buffered "
                         "upload re-enters (defaults: long enough to "
                         "demonstrate a round-r upload landing in r+2)")
    ap.add_argument("--hedge", type=float, default=None, metavar="FACTOR",
                    help="speculative hedging: replica races any "
                         "aggregator lagging FACTOR x its expected finish "
                         "(> 1.0; needs a non-barrier schedule)")
    ap.add_argument("--rounds", type=int, default=3)
    args = ap.parse_args(argv)
    if args.schedule == "quorum" and args.quorum is None:
        args.quorum = 12

    policy = None
    if args.staleness_policy is not None:
        if args.reentry_delay_s is None:
            # push a dropped client's re-entry past round r+1's cut so
            # the demo shows staleness s=2: upload from round r folds in
            # round r+2 (late clients re-enter at their probed completion
            # and typically land in r+1 with s=1)
            args.reentry_delay_s = 14.0
        policy = StalenessPolicy(
            kind=args.staleness_policy, alpha=args.staleness_alpha,
            max_staleness=4 if args.staleness_policy == "cutoff" else None,
            reentry_delay_s=args.reentry_delay_s)
        if args.deadline_s is None and args.schedule != "quorum":
            args.deadline_s = 8.0   # a cut is what creates stragglers

    faults = FaultModel(dropout_rate=0.10, stall_rate=0.15, stall_s=6.0,
                        failure_rate=0.30, retry_backoff_s=0.5,
                        seed=args.seed)
    session = FederatedSession(SessionConfig(
        topology="gradssharding", n_shards=M, schedule=args.schedule,
        upload=UploadModel(mbps=16.0, jitter_s=3.0, rate_jitter=0.5,
                           seed=11),
        faults=faults, participation_k=args.participation_k,
        deadline_s=args.deadline_s, quorum=args.quorum,
        staleness_policy=policy, hedge_factor=args.hedge))

    rng = np.random.default_rng(0)
    grads = [rng.standard_normal(GRAD_SIZE).astype(np.float32)
             for _ in range(N_CLIENTS)]

    print(f"cohort N={N_CLIENTS}, K={args.participation_k} sampled/round, "
          f"schedule={args.schedule}, fault seed={args.seed}")
    e_deliver = cm.expected_deliveries(N_CLIENTS, args.participation_k,
                                       faults.dropout_rate)
    print(f"expected deliveries/round: {e_deliver:.1f}, "
          f"expected attempts/invocation: "
          f"{cm.expected_attempts(faults.failure_rate):.3f}\n")

    for r in session.run(lambda rnd: grads, rounds=args.rounds):
        fresh = [grads[i] for i in r.arrivals]
        if r.stale_folded and policy is not None:
            # stale entries fold with their policy weight; fresh ones
            # weigh 1.0 — the exactness contract becomes the weighted
            # survivor mean
            w = [1.0] * len(fresh) \
                + [policy.weight(s) for _c, s in r.stale_folded]
            g = fresh + [grads[c] for c, _s in r.stale_folded]
            ref = np.average(np.stack(g), axis=0, weights=w) \
                .astype(np.float32)
        else:
            ref = np.mean(np.stack(fresh), axis=0).astype(np.float32)
        exact = np.allclose(r.avg_flat, ref, rtol=1e-5, atol=1e-6)
        rnd = session.rounds_run - 1
        stale = "".join(f", stale client {c} (s={s})"
                        for c, s in r.stale_folded)
        hedge = f", hedges={r.hedges}/{r.hedge_wins} won" \
            if args.hedge else ""
        print(f"round {rnd}: delivered {len(r.arrivals)}/"
              f"{len(r.participants)} "
              f"({r.delivered_fraction:.0%}), dropped={list(r.dropped)}, "
              f"late={list(r.late)}, retries={r.retries}{stale}{hedge}, "
              f"wall={r.wall_clock_s:.2f}s, survivor-mean exact: {exact}")
        assert exact

    totals = session.fault_totals
    print(f"\nsession: wall={session.session_wall_s:.2f}s, "
          f"total cost=${session.total_cost():.6f} "
          f"(lambda ${session.lambda_cost():.6f} + "
          f"s3 ${session.s3_cost():.6f})")
    if policy is not None or args.hedge:
        print(f"totals: {totals['stale_folded']} stale fold(s), "
              f"{totals['hedges']} hedge(s) ({totals['hedge_wins']} won), "
              f"{totals['retries']} retried attempt(s)")
    print("every failed attempt was retried and billed; the averages "
          "above are bit-exact over each round's survivors.")


if __name__ == "__main__":
    main()
