"""Batched serving with a KV-cache decode step under a (toy) mesh.

Greedy-decodes a batch of prompts with any ``--arch`` (reduced config on
CPU), exercising the same `make_serve_step` + cache partition specs the
512-chip dry-run compiles. Works for dense, SWA, MoE, SSM, hybrid and
enc-dec families.

Run:  PYTHONPATH=src python examples/serve_sharded.py --arch zamba2-2.7b
"""
import argparse

from repro.configs import arch_ids, get_arch
from repro.launch.serve import serve_loop


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b",
                    choices=arch_ids() + ["gpt2-large"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt_len", type=int, default=8)
    ap.add_argument("--new_tokens", type=int, default=24)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch).smoke
    out = serve_loop(cfg, batch=args.batch, prompt_len=args.prompt_len,
                     max_new_tokens=args.new_tokens,
                     max_len=args.prompt_len + args.new_tokens + 8)
    print(f"arch={args.arch} ({cfg.family}) "
          f"generated={out['generated'].shape} "
          f"throughput={out['tokens_per_s']:.1f} tok/s "
          f"wall={out['wall_s']:.2f}s")
    print("sample token ids:", out["generated"][0][:16].tolist())


if __name__ == "__main__":
    main()
