"""Quickstart: one GradsSharding aggregation round, end to end.

Shards 20 client gradients into M=4 pieces, aggregates each shard in an
independent simulated-Lambda function, reconstructs, and verifies the
result is bit-identical to full-vector FedAvg — the paper's central claim.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import aggregation as agg
from repro.core import cost_model as cm
from repro.serverless import LambdaRuntime
from repro.store import ObjectStore

N_CLIENTS, M, GRAD_SIZE = 20, 4, 100_000


def main():
    rng = np.random.default_rng(0)
    client_grads = [rng.standard_normal(GRAD_SIZE).astype(np.float32)
                    for _ in range(N_CLIENTS)]

    store, runtime = ObjectStore(), LambdaRuntime()
    result = agg.aggregate_round(
        "gradssharding", client_grads, rnd=0, store=store, runtime=runtime,
        n_shards=M)

    # the paper's equivalence claim: bit-identical to full-vector FedAvg
    reference = client_grads[0].copy()
    for g in client_grads[1:]:
        reference += g
    reference /= N_CLIENTS
    assert np.array_equal(result.avg_flat, reference)
    print(f"bit-identical to full FedAvg: True")

    ops = cm.s3_ops("gradssharding", N_CLIENTS, M)
    print(f"wall-clock (modeled): {result.wall_clock_s:.2f}s "
          f"in {len(result.phases_s)} phase(s)")
    print(f"S3 ops: {result.puts} PUTs + {result.gets} GETs "
          f"(Table II: {ops.puts}/{ops.gets})")
    print(f"peak aggregator memory: {result.peak_memory_mb:.0f} MB "
          f"(O(|θ|/M) + 450 MB runtime)")
    print(f"lambda cost: ${result.lambda_cost:.8f}, "
          f"s3 cost: ${result.s3_cost():.8f} per round")

    # compare against the tree baselines
    for topo in ("lambda_fl", "lifl"):
        s, r = ObjectStore(), LambdaRuntime()
        res = agg.aggregate_round(topo, client_grads, rnd=0, store=s,
                                  runtime=r)
        print(f"{topo:14s}: wall {res.wall_clock_s:.2f}s "
              f"({len(res.phases_s)} phases), "
              f"ops {res.puts}+{res.gets}, "
              f"allclose={np.allclose(res.avg_flat, reference, rtol=1e-5, atol=1e-6)}")


if __name__ == "__main__":
    main()
