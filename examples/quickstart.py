"""Quickstart: the session API in ~15 lines.

One ``SessionConfig`` declares the whole substrate (topology, shard count,
engine, schedule, upload model); ``session.round(grads)`` runs a simulated
serverless aggregation round. Swapping the topology — including the
``sharded_tree`` plugin registered via ``@register_topology`` — changes
cost and latency, never the learning result: GradsSharding is bit-identical
to full-vector FedAvg, and sharded_tree is bit-identical to λ-FL.

Run:  PYTHONPATH=src python examples/quickstart.py \
          [--schedule pipelined --readahead-k 4 --workers 4]
"""
import argparse

import numpy as np

from repro import FederatedSession, SessionConfig
from repro.core.cost_model import UploadModel

N_CLIENTS, M, GRAD_SIZE = 20, 4, 100_000


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--schedule", default=None,
                    choices=["barrier", "pipelined"])
    ap.add_argument("--readahead-k", type=int, default=None,
                    help="pipelined out-of-order prefetch window (GET up "
                         "to k contributions ahead of the fold frontier; "
                         "fold order, and thus the result bits, never "
                         "change)")
    ap.add_argument("--upload-mbps", type=float, default=None)
    ap.add_argument("--jitter-s", type=float, default=0.0)
    ap.add_argument("--codec", default=None,
                    choices=["identity", "fp16", "qsgd8", "topk"],
                    help="on-the-wire contribution format (default: "
                         "REPRO_AGG_CODEC / identity); lossy codecs are "
                         "deterministic and report codec_error")
    ap.add_argument("--workers", default=None,
                    help="host fold-pool width: an int or 'auto' "
                         "(default: REPRO_AGG_WORKERS / all host cores). "
                         "Folds shard across cores by element span, so "
                         "the result bits never depend on this")
    args = ap.parse_args(argv)

    upload = None
    if args.upload_mbps or args.jitter_s:
        upload = UploadModel(mbps=args.upload_mbps, jitter_s=args.jitter_s)

    rng = np.random.default_rng(0)
    grads = [rng.standard_normal(GRAD_SIZE).astype(np.float32)
             for _ in range(N_CLIENTS)]
    reference = np.mean(grads, axis=0, dtype=np.float32)

    results = {}
    for topology in ("gradssharding", "lambda_fl", "lifl", "sharded_tree"):
        session = FederatedSession(SessionConfig(
            topology=topology, n_shards=M, schedule=args.schedule,
            readahead_k=args.readahead_k, upload=upload, codec=args.codec,
            workers=args.workers))
        results[topology] = r = session.round(grads)
        print(f"{topology:14s}: wall {r.wall_clock_s:6.2f}s "
              f"({len(r.phases_s)} phase(s)), ops {r.puts}P+{r.gets}G, "
              f"peak-mem {r.peak_memory_mb:5.0f} MB, "
              f"cost ${session.total_cost():.8f}/round"
              + (f", codec_error {r.codec_error:.2e}"
                 if r.codec != "identity" else ""))

    if results["gradssharding"].codec == "identity":
        # the paper's equivalence claims, extended to the plugin topology
        # (exact bit-identity is the *identity* codec's contract; lossy
        # codecs guarantee determinism and a reported codec_error instead)
        assert np.array_equal(results["gradssharding"].avg_flat,
                              _streaming_mean(grads))
        assert np.array_equal(results["sharded_tree"].avg_flat,
                              results["lambda_fl"].avg_flat)
        for topology, r in results.items():
            assert np.allclose(r.avg_flat, reference, rtol=1e-5, atol=1e-6)
        print("gradssharding bit-identical to full FedAvg: True")
        print("sharded_tree bit-identical to lambda_fl:    True")


def _streaming_mean(grads):
    acc = grads[0].copy()
    for g in grads[1:]:
        acc += g
    return acc / len(grads)


if __name__ == "__main__":
    main()
