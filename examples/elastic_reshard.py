"""Elastic shard-count restart: checkpoint at M=4, resume at M=8.

The paper's "adaptive shard counts" future work: a training run saves its
state sharded by logical shard index; after a (simulated) failure the
deployment re-tunes M — e.g. the model grew past the per-function memory
budget — and the restart re-partitions without losing a step. Also shows
`min_shards_for` picking M automatically from the Lambda memory limit.

Run:  PYTHONPATH=src python examples/elastic_reshard.py
"""
import tempfile

import numpy as np

from repro.checkpoint import load_resharded, save_sharded
from repro.core import cost_model as cm
from repro.core.sharding import make_plan, reconstruct

MB = 1024 * 1024


def main():
    rng = np.random.default_rng(0)
    theta = rng.standard_normal(1_000_003).astype(np.float32)

    with tempfile.TemporaryDirectory() as d:
        plan4 = make_plan("uniform", theta.size, 4)
        save_sharded(d, theta, plan4, step=100,
                     extra={"note": "round 100, M=4"})
        print(f"saved step 100 at M=4: shard sizes {plan4.shard_sizes()}")

        # --- simulated operator decision: resume at M=8 -------------------
        shards, plan8, meta = load_resharded(d, 100, new_m=8)
        print(f"resumed at M=8: shard sizes {plan8.shard_sizes()} "
              f"(meta: {meta['extra']})")
        restored = reconstruct(shards, plan8)
        assert np.array_equal(restored, theta)
        print("state after reshard: bit-identical  ✓")

        # --- automatic M from the platform memory limit --------------------
        for grad_mb in (512, 2953, 5120, 10_240, 102_400):
            m = cm.min_shards_for(grad_mb * MB)
            mem = cm.lambda_memory_mb("gradssharding", grad_mb * MB, m)
            print(f"gradient {grad_mb:>7d} MB -> min M = {m:>3d} "
                  f"({mem:.0f} MB/function, limit 10,240)")


if __name__ == "__main__":
    main()
